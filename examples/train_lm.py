"""End-to-end driver: train an LM for a few hundred steps through the full
production stack (config → sharded state → pipeline → decoupled-dispatch
MoE → async checkpoints → restart).

Default is a ~small MoE run that finishes on this CPU container in a few
minutes; ``--full-100m`` selects a ~100M-parameter dense config (the
deliverable's target scale — expect ~hours on 1 CPU core; on real
accelerators the same flags run as-is).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""
import argparse
import sys

sys.argv0 = sys.argv[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dispatch", choices=["1s", "2s"], default="1s")
    args = ap.parse_args()

    from repro.launch import train as train_mod

    if args.full_100m:
        # olmo-family dense ~100M: 8L × d512 × ff2048, vocab 32k
        argv = ["--arch", "olmo-1b", "--steps", str(args.steps),
                "--batch", "8", "--seq", "256", "--devices", "8",
                "--mesh", "4x2", "--vocab", "32000",
                "--ckpt-dir", "/tmp/repro_train_100m", "--resume",
                "--log-every", "10"]
        # the smoke config is ~0.1M; scale it up via the full config's
        # little sibling: use full olmo-1b but reduced seq/steps is still
        # heavy on CPU — document the tradeoff, run the 100M variant
        import dataclasses
        from repro.configs import olmo_1b
        olmo_1b.SMOKE = dataclasses.replace(
            olmo_1b.SMOKE, n_layers=8, d_model=512, d_ff=2048,
            n_heads=8, n_kv_heads=8, vocab_size=32_000)
        argv.insert(0, "--smoke")
        train_mod.main(argv)
    else:
        # llama4-family reduced MoE — exercises the paper's decoupled
        # dispatch inside the train step (sized for the 1-core container;
        # raise batch/seq/devices freely on real hardware)
        train_mod.main([
            "--arch", "llama4-maverick-400b-a17b", "--smoke",
            "--steps", str(args.steps), "--batch", "4", "--seq", "64",
            "--devices", "4", "--mesh", "2x2",
            "--dispatch", args.dispatch,
            "--ckpt-dir", "/tmp/repro_train_moe", "--resume",
            "--log-every", "20"])


if __name__ == "__main__":
    main()
