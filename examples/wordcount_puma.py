"""PUMA-style Word-Count under imbalance — the paper's §3 experiment at
container scale, plus the engine-built vocabulary feeding the tokenizer
(the framework's ingest path).

    PYTHONPATH=src python examples/wordcount_puma.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import numpy as np

from repro.core.wordcount import WordCount
from repro.data.corpus import imbalance_repeats, synth_corpus
from repro.data.tokenizer import Vocab


def run_engine(tokens, backend, repeats, P=8):
    job = WordCount(backend=backend)
    job.init(tokens, vocab=65_536, task_size=4_096, push_cap=1_024,
             n_procs=P, repeats=repeats)
    job.run()                                   # compile + warm
    t0 = time.perf_counter()
    job.run()
    wall = time.perf_counter() - t0
    return job, wall


def main():
    P = 8
    tokens = synth_corpus(2_000_000, vocab=65_536, seed=0)
    T = (len(tokens) + 4_096 * P - 1) // (4_096 * P)

    print("=== balanced workload (paper Fig 4a/4b regime) ===")
    bal = imbalance_repeats(P, T, mode="balanced")
    job2, t2 = run_engine(tokens, "2s", bal)
    job1, t1 = run_engine(tokens, "1s", bal)
    print(f"MR-2S {t2:.2f}s | MR-1S {t1:.2f}s "
          f"({100 * (1 - t1 / t2):+.1f}%)")

    print("\n=== unbalanced workload (hot ranks compute 8x — Fig 4c/4d) ===")
    unb = imbalance_repeats(P, T, mode="unbalanced", hot_factor=8,
                            hot_fraction=0.125)
    job2u, t2u = run_engine(tokens, "2s", unb)
    job1u, t1u = run_engine(tokens, "1s", unb)
    print(f"MR-2S {t2u:.2f}s | MR-1S {t1u:.2f}s "
          f"({100 * (1 - t1u / t2u):+.1f}%)")
    assert job1u.result_dict() == job2u.result_dict() == job1.result_dict()

    # ingest path: the engine's counts build the LM tokenizer vocabulary
    counts = job1.result_dict()
    top = {f"word{k}".encode(): v for k, v in counts.items()}
    vocab = Vocab.from_counts(top, max_size=4_096)
    print(f"\nengine-built Vocab: size {vocab.size} "
          f"(top word id {max(counts, key=counts.get)}, "
          f"count {max(counts.values())})")


if __name__ == "__main__":
    main()
