"""PUMA-style Word-Count under imbalance — the paper's §3 experiment at
container scale on the unified Job API, plus the engine-built vocabulary
feeding the tokenizer (the framework's ingest path).

    PYTHONPATH=src python examples/wordcount_puma.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

from repro.core import JobConfig, submit
from repro.core.usecases import WordCount
from repro.data.corpus import imbalance_repeats, synth_corpus
from repro.data.tokenizer import Vocab


def run_engine(tokens, backend, repeats, P=8):
    cfg = JobConfig(usecase=WordCount(vocab=65_536), backend=backend,
                    task_size=4_096, push_cap=1_024, n_procs=P)
    submit(cfg, tokens, repeats=repeats).result()     # compile + warm
    return submit(cfg, tokens, repeats=repeats).result()


def main():
    P = 8
    tokens = synth_corpus(2_000_000, vocab=65_536, seed=0)
    T = (len(tokens) + 4_096 * P - 1) // (4_096 * P)

    print("=== balanced workload (paper Fig 4a/4b regime) ===")
    bal = imbalance_repeats(P, T, mode="balanced")
    res2 = run_engine(tokens, "2s", bal)
    res1 = run_engine(tokens, "1s", bal)
    print(f"MR-2S {res2.wall_time:.2f}s | MR-1S {res1.wall_time:.2f}s "
          f"({100 * (1 - res1.wall_time / res2.wall_time):+.1f}%)")

    print("\n=== unbalanced workload (hot ranks compute 8x — Fig 4c/4d) ===")
    unb = imbalance_repeats(P, T, mode="unbalanced", hot_factor=8,
                            hot_fraction=0.125)
    res2u = run_engine(tokens, "2s", unb)
    res1u = run_engine(tokens, "1s", unb)
    print(f"MR-2S {res2u.wall_time:.2f}s | MR-1S {res1u.wall_time:.2f}s "
          f"({100 * (1 - res1u.wall_time / res2u.wall_time):+.1f}%) "
          f"[imbalance {res1u.imbalance:.2f}]")
    assert res1u.records == res2u.records == res1.records

    # ingest path: the engine's counts build the LM tokenizer vocabulary
    counts = res1.records
    top = {f"word{k}".encode(): v for k, v in counts.items()}
    vocab = Vocab.from_counts(top, max_size=4_096)
    print(f"\nengine-built Vocab: size {vocab.size} "
          f"(top word id {max(counts, key=counts.get)}, "
          f"count {max(counts.values())})")


if __name__ == "__main__":
    main()
