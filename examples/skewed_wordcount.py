"""Skew-aware Word-Count — beating hash partitioning on a Zipf corpus.

Natural text is Zipf-distributed, so the paper's static ``hash(key) % P``
ownership rule floods a few owners' windows. This example runs the same
job under all three partitioners (``repro/core/partition.py``), shows
the owner-load imbalance each one produces, and verifies the results
are record-identical — partitioning is placement, never semantics.

It also demonstrates the combine-overflow guard: an undersized
``combine_capacity`` used to silently return wrong counts; it now
raises ``CombineOverflowError`` with the dropped-record count.

    PYTHONPATH=src python examples/skewed_wordcount.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core import (CombineOverflowError, JobConfig, SampledPartitioner,
                        submit)
from repro.core.partition import owner_loads, sample_key_histogram
from repro.core.planner import plan_input, read_tasks
from repro.core.usecases import WordCount
from repro.data.source import ZipfSource

P, N, VOCAB, TASK = 8, 500_000, 65_536, 4_096


def main():
    src = ZipfSource(N, vocab=VOCAB, a=1.8, seed=0)   # zipfy "natural text"
    uc = WordCount(vocab=VOCAB)

    base = None
    for part in ("hash", "sampled",
                 SampledPartitioner(split=True, split_threshold=0.05)):
        cfg = JobConfig(usecase=uc, backend="1s", task_size=TASK,
                        push_cap=1_024, n_procs=P, partitioner=part)
        with submit(cfg, src) as h:                   # handle is a CM:
            res = h.result()                          # feed never leaks
            # what would each rank receive under this owner map?
            plan = plan_input(N, TASK, P)
            hist = sample_key_histogram(
                lambda ids: read_tasks(src, plan, ids), plan, uc, 16)
            omap = np.asarray(h.carry.owner_map)[0]
            osplit = np.asarray(h.carry.owner_split)[0]
        load = owner_loads(hist, omap, osplit, P)
        print(f"{res.partitioner:<14} owner imbalance "
              f"{load.max() / load.mean():5.2f}   "
              f"split keys {res.n_split_keys:3d}   "
              f"records {len(res.records):,}")
        if base is None:
            base = res.records
        assert res.records == base                    # record-identical

    # --- the overflow guard ------------------------------------------------
    bad = JobConfig(usecase=uc, backend="1s", task_size=TASK,
                    push_cap=1_024, n_procs=P, combine_capacity=64)
    try:
        submit(bad, src).result()
    except CombineOverflowError as e:
        print(f"\ncombine_capacity=64 raises as it must: "
              f"{e.result.combine_overflow} records would have been "
              f"silently dropped pre-fix")


if __name__ == "__main__":
    main()
