"""Streaming Word-Count — the paper's non-blocking I/O on a dataset that
is never fully resident.

A memory-mapped token file (stand-in for the paper's 300GB PUMA corpus)
is streamed segment-by-segment: the SegmentFeed reads the next segment's
tasks by file offset in a background thread while the engines compute
the current one. Peak host residency is O(segment); the result is
bit-identical to the in-memory run.

    PYTHONPATH=src python examples/streaming_wordcount.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses
import tempfile

from repro.core import JobConfig, submit
from repro.core.usecases import WordCount
from repro.data.corpus import synth_corpus
from repro.data.source import ConcatSource, MmapTokenSource, ZipfSource


def main():
    # a sharded on-disk corpus: two mmap'd part files + a lazy synthetic
    # tail, presented as one stream (nothing below materializes it)
    d = tempfile.mkdtemp()
    for i in range(2):
        synth_corpus(400_000, vocab=65_536, seed=i).tofile(
            os.path.join(d, f"part-{i}.bin"))
    source = ConcatSource([
        MmapTokenSource(os.path.join(d, "part-0.bin")),
        MmapTokenSource(os.path.join(d, "part-1.bin")),
        ZipfSource(200_000, vocab=65_536, seed=9),
    ])
    print(f"streaming {source.len_elements():,} tokens "
          f"({source.len_elements() * 4 / 2**20:.0f} MiB on disk/lazy)")

    cfg = JobConfig(usecase=WordCount(vocab=65_536), backend="1s",
                    task_size=4_096, push_cap=1_024, n_procs=8,
                    segment=4)
    handle = submit(cfg, source)           # no pre-shard, no full read
    while handle.step():
        pass                               # next segment prefetches behind
    result = handle.result()

    st = handle.feed.stats
    print(f"{result.n_tasks} tasks in {result.wall_time:.2f}s | "
          f"{st.prefetch_hits}/{st.segments_built} segments prefetched, "
          f"peak feed residency {st.max_live_bytes / 2**20:.2f} MiB "
          f"vs {st.bytes_read / 2**20:.0f} MiB streamed")

    # identical answer from the bulk-synchronous engine over the stream
    ref = submit(dataclasses.replace(cfg, backend="2s"), source).result()
    assert ref.records == result.records
    print(f"MR-1S == MR-2S over the stream: OK "
          f"({len(ref.records)} unique words)")


if __name__ == "__main__":
    main()
