"""Serve a small model with batched requests (prefill → batched decode).

    PYTHONPATH=src python examples/serve_lm.py [--arch deepseek-v2-lite-16b]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b",
                    help="any assigned arch id (smoke-sized config)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    from repro.launch import serve as serve_mod
    serve_mod.main(["--arch", args.arch, "--smoke",
                    "--requests", str(args.requests),
                    "--batch", "8", "--prompt-len", "32",
                    "--new-tokens", str(args.new_tokens)])


if __name__ == "__main__":
    main()
