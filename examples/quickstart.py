"""Quickstart — the paper's Listing 1 on the unified Job API, in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

from repro.core import JobConfig, submit
from repro.core.usecases import WordCount
from repro.data.corpus import synth_corpus


def main():
    tokens = synth_corpus(500_000, vocab=65_536, seed=0)

    # paper Listing 1, redesigned: declare the use-case + backend, submit.
    # A raw array is auto-wrapped in an ArraySource and streamed through
    # the same SegmentFeed as any DataSource (mmap files, lazy corpora —
    # see examples/streaming_wordcount.py); nothing is pre-sharded.
    cfg = JobConfig(usecase=WordCount(vocab=65_536), backend="1s",
                    task_size=4_096, push_cap=1_024, n_procs=8)
    result = submit(cfg, tokens).result()
    print("top-10 words (id\tcount):")
    for k, v in sorted(result.records.items(), key=lambda kv: -kv[1])[:10]:
        print(f"{k}\t{v}")
    print(f"\n{result.n_tasks} tasks over {len(result.tasks_per_rank)} "
          f"ranks in {result.wall_time:.2f}s "
          f"(imbalance {result.imbalance:.2f})")

    # the bulk-synchronous reference (Hoefler et al.) gives the same answer
    import dataclasses
    ref = submit(dataclasses.replace(cfg, backend="2s"), tokens).result()
    assert ref.records == result.records
    print(f"MR-1S == MR-2S result: OK ({len(ref.records)} unique words)")


if __name__ == "__main__":
    main()
