"""Quickstart — the paper's Listing 1 on the JAX engine, in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core.wordcount import WordCount
from repro.data.corpus import synth_corpus


def main():
    tokens = synth_corpus(500_000, vocab=65_536, seed=0)

    # paper Listing 1: create job with the MR-1S back-end, Init, Run, Print
    job = WordCount(backend="1s")
    job.init(tokens, vocab=65_536, task_size=4_096, push_cap=1_024,
             n_procs=8)
    keys, vals = job.run()
    print("top-10 words (id\tcount):")
    job.print_result(top=10)
    job.finalize()

    # the bulk-synchronous reference (Hoefler et al.) gives the same answer
    ref = WordCount(backend="2s")
    ref.init(tokens, vocab=65_536, task_size=4_096, push_cap=1_024,
             n_procs=8)
    ref.run()
    assert job.result_dict() == ref.result_dict()
    print("\nMR-1S == MR-2S result: OK "
          f"({len(ref.result_dict())} unique words)")


if __name__ == "__main__":
    main()
